"""Shared layers for the model zoo.

Conventions:
  * Params are plain pytrees (nested dicts of jnp arrays).  Their structure
    is declared once as a tree of ``PSpec`` (shape + logical sharding axes +
    init), from which both real initialization (smoke tests / examples) and
    ShapeDtypeStruct stand-ins with NamedShardings (dry-run) are derived.
  * Activations are annotated with ``sharding.constrain`` using logical axes;
    with no active rule set this is an identity, so the same code runs on one
    CPU device and on the 512-chip mesh.
  * Attention is exact but *chunked* (online-softmax flash formulation in
    pure jnp) above ``CHUNK_THRESHOLD`` so 32k-sequence cells never
    materialize O(S²) score tensors.  The Pallas kernel in
    ``repro.kernels.flash_attention`` implements the same contract for TPU.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.sharding import constrain
from .config import ModelConfig

CHUNK_THRESHOLD = 8_192   # switch to chunked attention above this seq len
Q_CHUNK = 2_048
KV_CHUNK = 2_048


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones
    scale: Optional[float] = None  # stddev; None => 1/sqrt(fan_in = shape[-2])
    dtype: Optional[Any] = None    # None => caller's default (recurrent
                                   # states pin fp32 regardless of default)

    def stddev(self) -> float:
        if self.scale is not None:
            return self.scale
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        return 1.0 / math.sqrt(max(1, fan_in))


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def init_params(spec_tree, rng: jax.Array, dtype=jnp.float32):
    """Materialize a PSpec tree into arrays (deterministic per-path keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_pspec)
    keys = jax.random.split(rng, len(leaves))
    arrs = []
    for k, spec in zip(keys, leaves):
        dt = spec.dtype or dtype
        if spec.init == "zeros":
            arrs.append(jnp.zeros(spec.shape, dt))
        elif spec.init == "ones":
            arrs.append(jnp.ones(spec.shape, dt))
        else:
            arrs.append(
                (jax.random.normal(k, spec.shape) * spec.stddev()).astype(dt))
    return jax.tree_util.tree_unflatten(treedef, arrs)


def param_structs(spec_tree, rules, dtype=jnp.bfloat16):
    """ShapeDtypeStructs with shardings, for .lower() without allocation."""
    def mk(spec: PSpec):
        sh = rules.sharding(spec.axes, spec.shape) if rules else None
        return jax.ShapeDtypeStruct(spec.shape, spec.dtype or dtype,
                                    sharding=sh)
    return jax.tree_util.tree_map(mk, spec_tree, is_leaf=is_pspec)


def param_shardings(spec_tree, rules):
    return jax.tree_util.tree_map(
        lambda s: rules.sharding(s.axes, s.shape), spec_tree, is_leaf=is_pspec)


def stack_specs(spec_tree, n: int):
    """Add a leading layer-stack dim (for scan-over-layers)."""
    return jax.tree_util.tree_map(
        lambda s: PSpec((n,) + s.shape, (None,) + s.axes, s.init, s.scale,
                        s.dtype),
        spec_tree, is_leaf=is_pspec)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    # Variance in fp32 for stability, but the x path stays in its own dtype:
    # wholesale fp32 upcasts here made every SPMD-inserted all-reduce of the
    # residual-stream cotangent fp32 (2x wire bytes; see EXPERIMENTS §Perf).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + weight.astype(x.dtype))


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return (cap * jnp.tanh(x / cap)) if cap > 0 else x


# ---------------------------------------------------------------------------
# RoPE (standard, dual-theta, M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(hd: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float64) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, N, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions: (3, B, S) — temporal / height / width position streams.
    ``sections`` partitions the hd/2 frequency dims; each section takes its
    angle from the corresponding stream.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)  # (hd/2,)
    # (3, B, S, hd/2) angles per stream, then select per-section stream.
    ang_all = positions[..., None].astype(jnp.float32) * freqs
    sel = np.repeat(np.arange(3), np.array(sections))              # (hd/2,)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_all, 0, -1), jnp.asarray(sel)[None, None, :, None],
        axis=-1)[..., 0]                                           # (B,S,hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def text_positions(batch: int, seq: int, offset=0) -> jax.Array:
    return jnp.arange(seq)[None, :] + offset + jnp.zeros((batch, 1), jnp.int32)


def mrope_positions(batch: int, n_patches: int, n_text: int) -> jax.Array:
    """Stub VLM layout: image patches on a √n grid, then text tokens."""
    grid = max(1, int(math.ceil(math.sqrt(max(1, n_patches)))))
    idx = np.arange(n_patches)
    t = np.zeros(n_patches)
    h, w = idx // grid, idx % grid
    t_text = n_patches + np.arange(n_text)  # all three streams advance
    pos = np.stack([np.concatenate([t, t_text]),
                    np.concatenate([h, t_text]),
                    np.concatenate([w, t_text])])               # (3, S)
    return jnp.asarray(np.broadcast_to(pos[:, None, :],
                                       (3, batch, n_patches + n_text)),
                       dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Attention core (exact, chunked online-softmax)
# ---------------------------------------------------------------------------
def _gqa_scores(q, k):
    """q: (B,S,Nkv,G,hd)  k: (B,T,Nkv,hd) -> (B,Nkv,G,S,T) fp32."""
    return jnp.einsum("bsngh,btnh->bngst", q, k,
                      preferred_element_type=jnp.float32)


def _mask_bias(q_pos, k_pos, window: int) -> jax.Array:
    """Additive causal (+ optional sliding-window) bias, fp32."""
    keep = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        keep &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(keep, 0.0, -1e30).astype(jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0, cap: float = 0.0,
              q_offset: int = 0, kv_len: Optional[jax.Array] = None,
              ) -> jax.Array:
    """Exact attention. q:(B,S,Nq,hd) k,v:(B,T,Nkv,hd) -> (B,S,Nq,hd).

    * GQA via head grouping.
    * window>0: sliding-window (local) attention.
    * cap>0: gemma-style logit soft-capping.
    * q_offset: absolute position of q[0] (decode: q_offset=pos).
    * kv_len: dynamic valid KV length (decode against preallocated cache).
    Chooses the chunked online-softmax path for long sequences.
    """
    B, S, Nq, hd = q.shape
    T, Nkv = k.shape[1], k.shape[2]
    G = Nq // Nkv
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, S, Nkv, G, hd)

    # Chunking is for LONG QUERY sequences (train/prefill): it bounds the
    # live score tensor.  Decode (S==1/small) must NOT chunk — the chunked
    # reshape of the model-sharded cache seq dim defeats SPMD and
    # all-gathers the entire cache (observed: 53 GB/device/step on gemma2
    # decode_32k); the direct path keeps scores sharded on T and reduces
    # tiny (B,N,G,S) partials instead.
    if S > CHUNK_THRESHOLD:
        return _chunked_attention(qg, k, v, causal=causal, window=window,
                                  cap=cap, q_offset=q_offset, kv_len=kv_len
                                  ).reshape(B, S, Nq, hd)

    s = _gqa_scores(qg, k)                                # (B,Nkv,G,S,T)
    s = softcap(s, cap)
    q_pos = jnp.arange(S) + q_offset
    k_pos = jnp.arange(T)
    if causal:
        s = s + _mask_bias(q_pos, k_pos, window)
    if kv_len is not None:
        s = jnp.where((k_pos < kv_len)[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bngst,btnh->bsngh", p.astype(v.dtype), v)
    return o.reshape(B, S, Nq, hd)


def _chunked_attention(qg, k, v, *, causal, window, cap, q_offset, kv_len):
    """Flash-style exact attention: scan q-chunks × kv-chunks, fp32 running
    (max, sum, acc).  Never materializes more than (Bq_chunk × kv_chunk)."""
    B, S, Nkv, G, hd = qg.shape
    T = k.shape[1]
    qc = min(Q_CHUNK, S)
    kc = min(KV_CHUNK, T)
    n_q, n_k = -(-S // qc), -(-T // kc)
    pad_q, pad_k = n_q * qc - S, n_k * kc - T

    qg = jnp.pad(qg, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qs = qg.reshape(B, n_q, qc, Nkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(B, n_k, kc, Nkv, hd).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(B, n_k, kc, Nkv, hd).transpose(1, 0, 2, 3, 4)
    valid_t = T if kv_len is None else kv_len

    def q_step(_, qi_q):
        qi, qblk = qi_q
        q_pos = qi * qc + jnp.arange(qc) + q_offset

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_kv
            k_pos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bsngh,btnh->bngst", qblk, kblk,
                           preferred_element_type=jnp.float32)
            s = softcap(s, cap)
            keep = k_pos[None, :] < valid_t
            if causal:
                keep &= k_pos[None, :] <= q_pos[:, None]
                if window > 0:
                    keep &= (q_pos[:, None] - k_pos[None, :]) < window
            else:
                keep = jnp.broadcast_to(keep, (qc, kc))
            s = jnp.where(keep[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            pv = jnp.einsum("bngst,btnh->bngsh", p.astype(vblk.dtype), vblk)
            acc_new = acc * alpha[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Nkv, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Nkv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Nkv, G, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(n_k), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 3, 1, 2, 4)        # (B,qc,Nkv,G,hd)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(n_q), qs))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, n_q * qc, Nkv, G, hd)
    return out[:, :S].astype(v.dtype)


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------
def dense(x: jax.Array, w: jax.Array,
          use_axes: Optional[Tuple] = None) -> jax.Array:
    """x: (..., d_in) @ w: (d_in, d_out).

    ``use_axes`` is the weight's sharding AT USE TIME.  ZeRO-3/FSDP weights
    are stored with their contraction dim sharded over "data"; consuming
    them directly makes GSPMD resolve the data-axis conflict with the
    batch-sharded activations by REPLICATING THE ACTIVATION and partial-
    summing over d (observed: 2 × 30 GB/device full-batch all-reduces per
    layer on kimi-k2).  Constraining the weight to (None, "model") at use
    forces the cheap resolution: all-gather the weight (ZeRO-3 semantics),
    keep activations batch-sharded.
    """
    if use_axes is not None:
        w = constrain(w, use_axes)
    return jnp.einsum("...d,df->...f", x, w)


UP_W = (None, "model")     # use-time spec for (d_model, wide) weights
DOWN_W = ("model", None)   # use-time spec for (wide, d_model) weights


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(dense(x, w_gate, UP_W)) * dense(x, w_up, UP_W)
    h = constrain(h, ("batch", None, "model"))
    return dense(h, w_down, DOWN_W)
