"""Model configuration shared by all 10 assigned architectures.

One config dataclass covers the dense / MoE / VLM / audio / SSM / hybrid
families; the per-arch files in ``repro.configs`` instantiate it with the
exact published numbers.  Layers are described by a repeating ``pattern`` of
block kinds so the decoder can ``lax.scan`` over whole pattern-periods
(HLO size stays O(period), not O(n_layers) — this is what makes 94-layer
512-way SPMD compiles take seconds).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default: d_model // n_heads

    # ---- layer pattern ------------------------------------------------
    # Block kinds cycled over layers: "attn" (+MLP), "attn_local",
    # "mamba", "mlstm", "slstm".  len(pattern) is the scan period.
    pattern: Tuple[str, ...] = ("attn",)
    window: int = 4096               # sliding window for attn_local
    # MoE placement: layer i uses experts iff (i % moe_period == moe_offset)
    # and i >= moe_first_layer.  moe_period=0 disables MoE entirely.
    moe_period: int = 0
    moe_offset: int = 0

    # ---- attention details ---------------------------------------------
    rope_theta: float = 10_000.0
    local_rope_theta: Optional[float] = None  # gemma3 dual-theta
    mrope: bool = False              # qwen2-vl multimodal 3-section RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    attn_softcap: float = 0.0        # gemma2 logit soft-capping
    final_softcap: float = 0.0
    qk_norm: bool = False            # gemma3
    post_norm: bool = False          # gemma2/3 post-block RMSNorm

    # ---- MoE ------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0             # defaults to d_ff when MoE is on
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # ---- SSM (mamba) ------------------------------------------------------
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2

    # ---- xLSTM -----------------------------------------------------------
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # ---- scaling tweaks (minicpm μP-ish, gemma) ---------------------------
    embed_scale: float = 1.0         # multiply embeddings (gemma √d, minicpm 12)
    residual_scale: float = 1.0      # scale block outputs (minicpm depth-scale)
    logit_divisor: float = 1.0       # divide final logits (minicpm d/256)
    tie_embeddings: bool = True

    # ---- modality frontend stub -------------------------------------------
    # tokens: ids -> embedding table;  embeds: precomputed frame embeddings
    # mixed:  patch_embeds prefix + token ids (VLM)
    input_mode: str = "tokens"
    patch_frac: float = 0.25         # VLM: fraction of seq that is patches

    # ---- numerics ----------------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # ---- notes for DESIGN/EXPERIMENTS ---------------------------------------
    source: str = ""
    notes: str = ""

    # ------------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Pad vocab to 256 for clean TP sharding (standard prod practice)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def full_pattern(self) -> Tuple[str, ...]:
        """Pattern expanded to n_layers (scan periods + unrolled remainder)."""
        p = []
        while len(p) < self.n_layers:
            p.extend(self.pattern)
        return tuple(p[: self.n_layers])

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def remainder_layers(self) -> int:
        return self.n_layers % len(self.pattern)

    def is_moe_layer(self, i: int) -> bool:
        return (self.moe_period > 0 and i >= self.moe_offset
                and (i - self.moe_offset) % self.moe_period == 0)

    @property
    def has_full_attention(self) -> bool:
        return any(k == "attn" for k in self.full_pattern)

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: no *global* full-attention prefill cost.

        Per the assignment: run long-context decode for SSM/hybrid archs;
        sliding-window-only attention would also qualify, but every assigned
        windowed arch (gemma2/3) interleaves global layers.
        """
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Exact parameter count (used for 6·N·D model-FLOPs in roofline)."""
        d, hd = self.d_model, self.hd
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = self.padded_vocab * d  # embeddings
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        eff = self.expert_d_ff or self.d_ff
        for i, kind in enumerate(self.full_pattern):
            if kind in ("attn", "attn_local"):
                total += d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
                total += 2 * d  # norms
                if self.qk_norm:
                    total += 2 * hd
            elif kind == "mamba":
                di = self.ssm_expand * d
                total += 2 * d * di + di * self.ssm_conv + \
                    di * (2 * self.ssm_state + 1) + di + di * d + d
            elif kind == "mlstm":
                di = int(self.mlstm_proj_factor * d)
                total += 2 * d * di + di * d + 3 * di * di // 4 + 3 * di + d
            elif kind == "slstm":
                di = d
                total += 4 * d * di + 4 * di + d
                fh = int(self.slstm_proj_factor * d)
                total += 2 * d * fh + fh * d
            # FFN (attn/mamba blocks carry one, unless replaced by MoE)
            if kind in ("attn", "attn_local", "mamba"):
                if self.is_moe_layer(i):
                    total += self.n_experts * 3 * d * eff
                    total += d * self.n_experts  # router
                    total += self.n_shared_experts * 3 * d * eff
                elif self.d_ff > 0:
                    total += 3 * d * self.d_ff
                total += d  # ffn norm
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.moe_period == 0:
            return self.param_count()
        d = self.d_model
        eff = self.expert_d_ff or self.d_ff
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.is_moe_layer(i))
        inactive = n_moe_layers * (self.n_experts - self.experts_per_token) \
            * 3 * d * eff
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=max(2, len(cfg.pattern)) if cfg.remainder_layers == 0
        else len(cfg.pattern) + cfg.remainder_layers,
        d_model=64,
        n_heads=max(2, min(cfg.n_heads, 4)),
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        window=32,
        n_experts=min(cfg.n_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        expert_d_ff=64 if cfg.n_experts else 0,
        ssm_state=8,
        mrope_sections=(2, 3, 3),  # sums to head_dim/2 = 8
    )
    if cfg.n_layers % len(cfg.pattern) == 0:
        changes["n_layers"] = len(cfg.pattern) * min(2, cfg.n_periods)
    return dataclasses.replace(cfg, **changes)
