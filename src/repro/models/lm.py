"""Decoder LM assembly: embeddings → scan-over-periods → head.

The layer stack is grouped into repeating *periods* (cfg.pattern); one
``lax.scan`` step applies a whole period with stacked params, so HLO size is
O(period), independent of depth.  Layers past the last full period (pattern
remainder, e.g. gemma3's 34 = 5×6 + 4) are applied unrolled with their own
params.

Three entry points, matching the assigned shape kinds:
  forward(params, batch)             train-mode logits + loss
  prefill(params, batch, max_len)    logits for last position + full cache
  decode_step(params, batch, cache)  one token against the cache
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..launch.sharding import constrain
from .blocks import Ctx, layer_apply, layer_specs, MIXERS
from .config import ModelConfig
from .layers import (PSpec, dense, init_params, mrope_positions, rms_norm,
                     softcap, text_positions)


# ---------------------------------------------------------------------------
# Param / cache spec trees
# ---------------------------------------------------------------------------
def _stack(tree, n: int):
    return jax.tree_util.tree_map(
        lambda s: PSpec((n,) + s.shape, (None,) + s.axes, s.init, s.scale,
                        s.dtype),
        tree, is_leaf=lambda x: isinstance(x, PSpec))


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    specs: Dict[str, Any] = {
        "embed": PSpec((cfg.padded_vocab, d), ("model", "fsdp"), scale=0.02),
        "final_ln": PSpec((d,), (None,), init="zeros"),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = PSpec((d, cfg.padded_vocab), ("fsdp", "model"))
    if cfg.input_mode in ("embeds", "mixed"):
        specs["frontend_proj"] = PSpec((d, d), ("fsdp", "model"))
    period = len(cfg.pattern)
    if cfg.n_periods > 0:
        specs["layers"] = {
            f"p{p}": _stack(layer_specs(cfg, p), cfg.n_periods)
            for p in range(period)
        }
    for r in range(cfg.remainder_layers):
        li = cfg.n_periods * period + r
        specs[f"rem{r}"] = layer_specs(cfg, li)
    return specs


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    period = len(cfg.pattern)
    out: Dict[str, Any] = {}
    if cfg.n_periods > 0:
        out["layers"] = {
            f"p{p}": _stack(
                MIXERS[cfg.pattern[p]][2](cfg, batch, max_len), cfg.n_periods)
            for p in range(period)
        }
    for r in range(cfg.remainder_layers):
        kind = cfg.full_pattern[cfg.n_periods * period + r]
        out[f"rem{r}"] = MIXERS[kind][2](cfg, batch, max_len)
    return out


# ---------------------------------------------------------------------------
# Embedding frontend (token / embeds / mixed stubs)
# ---------------------------------------------------------------------------
def embed_inputs(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    emb = params["embed"]
    if cfg.input_mode == "tokens":
        x = emb[batch["tokens"]]
    elif cfg.input_mode == "embeds":
        x = dense(batch["frame_embeds"].astype(emb.dtype),
                  params["frontend_proj"])
    else:  # mixed (VLM): projected patch embeddings + token embeddings
        patches = dense(batch["patch_embeds"].astype(emb.dtype),
                        params["frontend_proj"])
        text = emb[batch["tokens"]]
        x = jnp.concatenate([patches, text], axis=1)
    x = x * jnp.asarray(cfg.embed_scale, x.dtype)
    return constrain(x, ("batch", None, None))


def _positions(cfg: ModelConfig, batch, B: int, S: int, offset=0):
    if cfg.mrope:
        n_text = batch["tokens"].shape[1] if "tokens" in batch else 0
        n_patch = S - n_text
        pos = mrope_positions(B, n_patch, n_text)
        if not isinstance(offset, int) or offset != 0:
            pos = pos + offset
        return pos
    return text_positions(B, S, offset)


# ---------------------------------------------------------------------------
# Layer-stack application
# ---------------------------------------------------------------------------
def _layer_ctx(cfg: ModelConfig, kind: str, mode: str, positions, cache,
               pos_offset, max_len) -> Ctx:
    theta = cfg.rope_theta
    window = 0
    if kind == "attn_local":
        window = cfg.window
        if cfg.local_rope_theta is not None:
            theta = cfg.local_rope_theta
    return Ctx(mode=mode, positions=positions, theta=theta, window=window,
               cache=cache, pos_offset=pos_offset, max_len=max_len)


REMAT_POLICIES = {
    "none": None,
    "dots": "dots",
    "full": "full",
}


def _remat_wrap(fn, remat: str):
    if remat == "full":
        return jax.checkpoint(fn, policy=None)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def run_layers(cfg: ModelConfig, params, x, *, mode: str, positions,
               cache=None, pos_offset=0, max_len: int = 0,
               remat: str = "none"):
    period = len(cfg.pattern)
    aux_total = 0.0
    new_cache: Dict[str, Any] = {}

    if cfg.n_periods > 0:
        def period_step(carry, scanned):
            h, aux = carry
            layer_params, layer_cache = scanned
            caches_out = {}
            for p, kind in enumerate(cfg.pattern):
                ctx = _layer_ctx(cfg, kind, mode, positions,
                                 None if layer_cache is None
                                 else layer_cache[f"p{p}"],
                                 pos_offset, max_len)
                h, c_out, a = layer_apply(cfg, kind, cfg.is_moe_layer(p),
                                          layer_params[f"p{p}"], h, ctx)
                aux = aux + a
                if c_out is not None:
                    caches_out[f"p{p}"] = c_out
            return (h, aux), (caches_out if caches_out else None)

        scan_cache = cache.get("layers") if cache else None
        if scan_cache is None:
            body = _remat_wrap(lambda c, lp: period_step(c, (lp, None)), remat)
            (x, aux_total), ys = jax.lax.scan(body, (x, 0.0), params["layers"])
        else:
            body = _remat_wrap(period_step, remat)
            (x, aux_total), ys = jax.lax.scan(
                body, (x, 0.0), (params["layers"], scan_cache))
        if ys is not None:
            new_cache["layers"] = ys

    for r in range(cfg.remainder_layers):
        li = cfg.n_periods * period + r
        kind = cfg.full_pattern[li]
        ctx = _layer_ctx(cfg, kind, mode, positions,
                         None if cache is None else cache.get(f"rem{r}"),
                         pos_offset, max_len)
        x, c_out, a = layer_apply(cfg, kind, cfg.is_moe_layer(li),
                                  params[f"rem{r}"], x, ctx)
        aux_total = aux_total + a
        if c_out is not None:
            new_cache[f"rem{r}"] = c_out
    return x, (new_cache if new_cache else None), aux_total


def _head(cfg: ModelConfig, params, x):
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = dense(x, params["unembed"])
    logits = logits / jnp.asarray(cfg.logit_divisor, logits.dtype)
    logits = softcap(logits, cfg.final_softcap)
    return constrain(logits, ("batch", None, "model"))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, batch, *,
            remat: str = "none") -> Tuple[jax.Array, jax.Array]:
    """Train-mode: next-token cross-entropy over the whole sequence."""
    x = embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = _positions(cfg, batch, B, S)
    x, _, aux = run_layers(cfg, params, x, mode="train", positions=positions,
                           remat=remat)
    logits = _head(cfg, params, x)
    labels = batch["labels"]
    # Shift: predict token t+1 at position t; ignore label < 0.
    # The gold logit is picked with a fused iota-compare-select reduction
    # instead of take_along_axis: gathering along the vocab-sharded axis
    # would all-gather the full logits (16+ GB/device at train_4k scale).
    lg = logits[:, :-1].astype(jnp.float32)
    lb = labels[:, 1:]
    mask = (lb >= 0).astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    viota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
    gold = jnp.sum(jnp.where(viota == lb[..., None], lg, 0.0), axis=-1)
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    if isinstance(aux, jax.Array) or aux:
        loss = loss + cfg.router_aux_coef * aux / max(1, cfg.n_layers)
    return loss, logits


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    """Process the prompt; return (last-position logits, cache, next_pos)."""
    x = embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = _positions(cfg, batch, B, S)
    x, cache, _ = run_layers(cfg, params, x, mode="prefill",
                             positions=positions, max_len=max_len)
    logits = _head(cfg, params, x[:, -1:])
    return logits, cache, S


def decode_step(cfg: ModelConfig, params, batch, cache, pos):
    """One decode step at absolute position ``pos`` (scalar int32)."""
    x = embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    if cfg.mrope:
        positions = jnp.broadcast_to(pos, (3, B, S)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(pos, (B, S)).astype(jnp.int32)
    x, new_cache, _ = run_layers(cfg, params, x, mode="decode",
                                 positions=positions, cache=cache,
                                 pos_offset=pos,
                                 max_len=0)
    logits = _head(cfg, params, x)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Convenience: real init for tests/examples
# ---------------------------------------------------------------------------
def init_model(cfg: ModelConfig, rng: jax.Array, dtype=jnp.float32):
    return init_params(model_specs(cfg), rng, dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype or dtype),
        cache_specs(cfg, batch, max_len),
        is_leaf=lambda x: isinstance(x, PSpec))
