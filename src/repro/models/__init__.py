"""Model zoo: 10 assigned architectures over shared decoder substrate."""
from .config import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                     TRAIN_4K, ModelConfig, ShapeConfig, smoke)
from .lm import (cache_specs, decode_step, forward, init_cache, init_model,
                 model_specs, prefill)

__all__ = ["ModelConfig", "ShapeConfig", "smoke", "ALL_SHAPES", "TRAIN_4K",
           "PREFILL_32K", "DECODE_32K", "LONG_500K", "forward", "prefill",
           "decode_step", "model_specs", "cache_specs", "init_model",
           "init_cache"]
