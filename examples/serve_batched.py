"""Transactional serving example: sessions commit every decode step.

Eight closed-loop clients stream inference sessions through the serving
engine (``repro.serve``): steps coalesce in the continuous batcher, run a
batched decode (the Pallas flash-decode kernel when jax is importable, a
latency-modeled stub otherwise), and each step's KV-cache update COMMITS
as a distributed transaction — here via Cornus, so a step costs one forced
LogOnce vote per KV partition and nothing else.  Mid-run, a background
publisher commits a checkpoint epoch through the same store while serving
continues.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.serve import (AdmissionConfig, EngineConfig, SessionConfig,
                         run_serve)

cfg = EngineConfig(
    session=SessionConfig(protocol="cornus", backend="replicated",
                          replication=3, kv_partitions=8,
                          participants_per_txn=2, service_delay_ms=1.0),
    # Generous deadline: off-TPU the interpret-mode kernel costs ~1s per
    # batch, and the example is about the commit path, not decode speed.
    admission=AdmissionConfig(max_batch=4, window_ms=1.5,
                              deadline_ms=30_000.0),
    decode="auto",                 # pallas flash-decode if jax is present
    # Small attention geometry: off-TPU the kernel runs in interpret mode,
    # where big grids make an example crawl.
    decode_kwargs=dict(slots=16, q_heads=2, kv_heads=1, head_dim=32,
                       max_len=64, block_kv=32),
    clients=8, steps_per_session=12,
    publish_at=0.4, publish_until=0.8, publish_interval_s=0.2)

result = run_serve(cfg)
rep = result.report
print(f"[serve] protocol={rep.protocol} committed={rep.committed} "
      f"aborted={rep.aborted} dropped={rep.dropped}")
print(f"[serve] tput={rep.throughput_tps:.1f} steps/s "
      f"goodput={rep.goodput_tps:.1f}/s mean_batch={rep.mean_batch:.2f}")
print(f"[serve] p50={rep.p50_ms:.2f}ms p99={rep.p99_ms:.2f}ms "
      f"(tail amp {rep.tail_amplification:.2f}) "
      f"ttft_p50={rep.ttft_p50_ms:.2f}ms")
print(f"[serve] publishes={len(result.publishes)} "
      f"(window tput ratio "
      f"{rep.publish_disruption if rep.publish_disruption else 'n/a'}), "
      f"fast_path_ops={result.counters['fast_path_ops']:.0f}")
