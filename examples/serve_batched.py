"""Batched serving example (deliverable b): gemma2-style reduced model,
8 requests served in waves of 4 with prefill + jitted decode and
temperature sampling.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.launch.serve import BatchServer, Request, ServeConfig
from repro.models import init_model, smoke

cfg = smoke(get_config("gemma2-2b"))   # local/global attention + softcaps
params = init_model(cfg, jax.random.key(0))
server = BatchServer(cfg, params, batch_size=4,
                     scfg=ServeConfig(max_new_tokens=24, temperature=0.8,
                                      top_k=50, max_len=128))
rng = np.random.RandomState(0)
reqs = [Request(i, rng.randint(0, cfg.vocab_size, (12 + i % 5,))
                .astype(np.int32)) for i in range(8)]
out = server.serve(reqs)
for rid in sorted(out)[:3]:
    print(f"[serve] req {rid}: prompt {reqs[rid].prompt[:6]}... -> "
          f"{out[rid][:10]}...")
tput = server.stats["tokens"] / server.stats["wall_s"]
print(f"[serve] {server.stats['requests']:.0f} requests, "
      f"{server.stats['tokens']:.0f} tokens, {tput:.1f} tok/s, "
      f"{server.stats['waves']:.0f} waves")
