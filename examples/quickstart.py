"""Quickstart: the three layers of the framework in one script.

1. The paper's protocol, raw: one Cornus commit vs one 2PC commit on the
   simulated Azure-Blob storage — watch the decision-log write disappear.
2. A reduced llama3.2 model: one training step + loss.
3. A Cornus-committed checkpoint of that model, then a restore.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp

# --- 1. the protocol ------------------------------------------------------
from repro.core import (AZURE_BLOB, Cluster, ProtocolConfig, Sim, SimStorage,
                        TxnSpec)

for proto in ("2pc", "cornus"):
    sim = Sim()
    cluster = Cluster(sim, SimStorage(sim, AZURE_BLOB, seed=0),
                      ["n0", "n1", "n2", "n3"],
                      ProtocolConfig(protocol=proto))
    done = cluster.run_txn(TxnSpec(txn_id="t1", coordinator="n0",
                                   participants=["n0", "n1", "n2", "n3"]))
    sim.run(until=1000)
    out = done.value
    print(f"[protocol] {proto:6s}: {out.decision.value:6s} "
          f"caller latency {out.caller_latency_ms:6.2f} ms "
          f"(prepare {out.prepare_ms:.2f} + commit {out.commit_ms:.2f})")

# --- 2. a model step -------------------------------------------------------
from repro.configs import get_config
from repro.models import forward, init_model, smoke

cfg = smoke(get_config("llama3.2-1b"))
params = init_model(cfg, jax.random.key(0))
tokens = jax.random.randint(jax.random.key(1), (4, 64), 0, cfg.vocab_size)
loss, logits = jax.jit(lambda p, b: forward(cfg, p, b))(
    params, {"tokens": tokens, "labels": tokens})
print(f"[model]    {cfg.name}(smoke): loss {float(loss):.3f}, "
      f"logits {logits.shape}")

# --- 3. Cornus-committed checkpoint ----------------------------------------
from repro.ckpt import (CornusCheckpointer, latest_committed, pack_tree,
                        partition_leaves, restore_params)
from repro.core.storage import FileStore

with tempfile.TemporaryDirectory() as d:
    store = FileStore(d)
    hosts = ["host0", "host1"]
    parts = partition_leaves(params, len(hosts))
    for h, keys in zip(hosts, parts):
        CornusCheckpointer(store, h, hosts).vote(1, pack_tree(params, keys))
    decision, _ = CornusCheckpointer(store, hosts[0], hosts).resolve(1)
    print(f"[ckpt]     epoch 1 {decision.value}; latest committed = "
          f"{latest_committed(store, hosts)}")
    restored = restore_params(store, hosts, 1,
                              jax.tree_util.tree_map(jnp.zeros_like, params))
    same = all(bool(jnp.allclose(a, b)) for a, b in zip(
        jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(restored)))
    print(f"[ckpt]     restore bit-exact: {same}")
