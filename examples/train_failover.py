"""End-to-end driver (deliverable b): train a byte-level LM on this repo's
own source code, kill it mid-checkpoint, restart, and show the resumed run
reproduces the unkilled loss curve — Cornus restore + stateless data
pipeline, end to end.

Run:  PYTHONPATH=src python examples/train_failover.py
"""
import os
import tempfile

import numpy as np

from repro.launch.train import MidCheckpointCrash, RunConfig, train

CORPUS = os.path.join(os.path.dirname(__file__), "..", "src", "repro",
                      "core", "protocol.py")


def cfg(ckpt_dir, **kw):
    base = dict(arch="llama3.2-1b", steps=60, batch=8, seq_len=128,
                ckpt_every=20, ckpt_dir=ckpt_dir, n_hosts=4,
                data_source=f"bytes:{os.path.abspath(CORPUS)}",
                lr=3e-3, log_every=20, seed=3)
    base.update(kw)
    return RunConfig(**base)


with tempfile.TemporaryDirectory() as d:
    golden = train(cfg(d + "/golden"))
    print(f"[golden ] {golden.steps_done} steps, "
          f"loss {golden.losses[0]:.3f} -> {golden.losses[-1]:.3f}, "
          f"{len(golden.ckpt_outcomes)} committed checkpoints")

    try:
        train(cfg(d + "/crash", die_mid_checkpoint_at=40))
    except MidCheckpointCrash as e:
        print(f"[crash  ] {e} — epoch 40 left in-flight on storage")

    resumed = train(cfg(d + "/crash", resume=True))
    print(f"[resume ] restored epoch {resumed.restored_from} "
          f"(in-flight epoch 40 force-aborted, never waited on)")
    drift = float(np.max(np.abs(
        np.array(resumed.losses) - np.array(golden.losses[20:]))))
    print(f"[resume ] loss-curve drift vs golden steps 20..60: {drift:.2e} "
          f"({'EXACT' if drift < 1e-5 else 'MISMATCH'})")
