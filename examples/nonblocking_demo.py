"""The paper's headline failure case, side by side (Fig 2b vs Fig 4a):
coordinator dies after collecting votes, before sending any decision.

2PC participants block forever; Cornus participants resolve through the
storage-level termination protocol in ~2 storage RTTs.

Run:  PYTHONPATH=src python examples/nonblocking_demo.py
"""
from repro.core import (AZURE_REDIS, Cluster, Decision, ProtocolConfig, Sim,
                        SimStorage, TxnSpec)

NODES = ["n0", "n1", "n2", "n3"]

for proto in ("2pc", "cornus"):
    sim = Sim()
    cluster = Cluster(sim, SimStorage(sim, AZURE_REDIS, seed=7), NODES,
                      ProtocolConfig(protocol=proto))
    cluster.fail("n0", 1.0)   # dies before any vote lands — decision unsent
    cluster.run_txn(TxnSpec(txn_id="t", coordinator="n0",
                            participants=NODES))
    sim.run(until=120_000)

    print(f"--- {proto} ---")
    for n in NODES[1:]:
        st = cluster.local.get((n, "t"), {})
        d = st.get("decision")
        blocked = cluster.blocked.get(("t", n), False)
        out = cluster.outcomes.get(("t", n))
        t_ms = f"{out.termination_ms:.2f} ms" if out and out.ran_termination \
            else "-"
        print(f"  {n}: decision={d.value if d else 'BLOCKED':9s} "
              f"blocked={blocked} termination={t_ms}")
